"""Schedule-plan IR tests.

Three layers:

1. **Property tests** (pure python, no devices): every causal plan covers
   each (q-chunk × kv-chunk) causal pair **exactly once** for P ∈ 1..8 —
   even and odd P, zigzag's 2P half-chunking included — via the
   ``plan_coverage`` simulator, which walks the executor's routing and
   evaluates every Work item's mask exactly as the kernel would.  Windowed
   and document plans additionally prove that **skipped steps are
   provably all-masked**: coverage still equals the global mask exactly
   even though steps were dropped.

2. **Differential tests vs the frozen seed implementations**
   (core/legacy_schedules.py): the plan executors reproduce the
   hand-written ring/balanced/zigzag loops bit-for-bit on 8 host devices,
   forward and backward, causal and document.

3. **Oracle differentials for the new capabilities**: windowed
   balanced/zigzag (strictly fewer ring steps than causal), static
   document boundaries on the ring family (no segment arrays shipped),
   and ``schedule="auto"`` resolution across every supported mask kind,
   forward and grads, on 1- and 8-device meshes.
"""
import numpy as np
import pytest

from repro.core import mask as mk
from repro.core import schedule as sp


# --------------------------------------------------------------------------
# 1. Exactly-once coverage properties (no devices needed)
# --------------------------------------------------------------------------

def _assert_exact(plan, segments=None):
    T = plan.P * plan.Tl
    cov = sp.plan_coverage(plan, segments=segments)
    truth = sp.global_allow(plan.mask, T, segments=segments).astype(np.int64)
    assert np.array_equal(cov, truth), (
        plan.name, plan.P, plan.mask,
        np.argwhere(cov != truth)[:4].tolist())


@pytest.mark.parametrize("sched", ["ring", "balanced", "zigzag"])
@pytest.mark.parametrize("P", list(range(1, 9)))
def test_causal_coverage_exactly_once(sched, P):
    """ACCEPTANCE: every causal (q, kv) pair computed exactly once, and no
    non-causal pair ever, for P ∈ 1..8 (zigzag splits into 2P chunks)."""
    _assert_exact(sp.build_plan(sched, mk.causal(), P, 8))


@pytest.mark.parametrize("sched", ["ring", "balanced", "zigzag"])
@pytest.mark.parametrize("P", [1, 3, 4, 7, 8])
@pytest.mark.parametrize("w", [1, 3, 9, 24, 1000])
def test_windowed_coverage_and_step_skipping(sched, P, w):
    """Windowed plans skip provably all-masked steps — coverage stays
    exactly-once against the banded global mask, and the executed step
    count shrinks when the window allows."""
    m = mk.sliding_window(w)
    plan = sp.build_plan(sched, m, P, 8)
    _assert_exact(plan)
    assert plan.exec_steps <= plan.total_steps
    if P >= 4 and w <= 3:
        # window inside one chunk: at most the distance-1 neighbours remain
        causal_steps = sp.build_plan(sched, mk.causal(), P, 8).exec_steps
        assert plan.exec_steps < causal_steps, (sched, P, w)


@pytest.mark.parametrize("sched", ["ring", "balanced", "zigzag"])
@pytest.mark.parametrize("P", [1, 2, 5, 8])
@pytest.mark.parametrize("n_docs", [1, 3, 6])
def test_document_boundary_coverage_and_pruning(sched, P, n_docs):
    """Static document boundaries: coverage is exact with no segment
    arrays at all, and steps no document spans are statically pruned."""
    Tl = 8
    T = P * Tl
    bnd = mk.doc_boundaries(T, n_docs)
    m = mk.document(boundaries=bnd)
    plan = sp.build_plan(sched, m, P, Tl)
    _assert_exact(plan)
    if sched in ("ring", "balanced") and P == 8 and n_docs == 6:
        # short docs cannot span distant chunk pairs: steps must drop
        assert plan.exec_steps < plan.total_steps


@pytest.mark.parametrize("sched", ["ring", "balanced", "zigzag"])
@pytest.mark.parametrize("P", [2, 5, 8])
def test_dynamic_segment_coverage(sched, P):
    """Dynamic (runtime segment-ID) document masks: the plan can't prune,
    but per-step segment shipping still yields exactly-once coverage."""
    Tl = 8
    T = P * Tl
    seg = mk.segments_from_boundaries(T, mk.doc_boundaries(T, 4))
    plan = sp.build_plan(sched, mk.document(), P, Tl)
    _assert_exact(plan, segments=seg)
    assert plan.exec_steps == plan.total_steps   # nothing provable


def test_windowed_document_combined_coverage():
    """window ∧ document compose: both pruning sources apply."""
    P, Tl = 8, 8
    bnd = mk.doc_boundaries(P * Tl, 4)
    m = mk.document(boundaries=bnd, window=10)
    for sched in ("ring", "balanced", "zigzag"):
        plan = sp.build_plan(sched, m, P, Tl)
        _assert_exact(plan)
        assert plan.exec_steps < plan.total_steps, sched


def test_full_mask_ring_coverage():
    """Bidirectional (encoder) ring: P steps cover everything once."""
    for P in (1, 3, 8):
        _assert_exact(sp.build_plan("ring", mk.full(), P, 8))


def test_plan_static_shape_properties():
    """Plan bookkeeping the benchmarks publish: step counts, kernel
    calls, container usage."""
    p_c = sp.build_plan("balanced", mk.causal(), 8, 8)
    assert (p_c.exec_steps, p_c.total_steps) == (4, 4)
    assert p_c.ship_q and p_c.uses_ring
    p_w = sp.build_plan("balanced", mk.sliding_window(17), 8, 8)
    assert p_w.exec_steps == 2 and not p_w.ship_q  # helper-free band
    p_z = sp.build_plan("zigzag", mk.causal(), 8, 8)
    assert p_z.n_chunks == 2 and not p_z.ship_q
    p_r = sp.build_plan("ring", mk.sliding_window(1), 8, 8)
    assert p_r.exec_steps == 0                     # diagonal-only window
    # multi-hop shift folding: skipped steps accumulate into shifts
    p_zw = sp.build_plan("zigzag", mk.sliding_window(9), 8, 16)
    assert sum(s.shift for s in p_zw.steps) <= p_zw.total_steps
    assert p_zw.exec_steps < p_zw.total_steps


def test_plan_cost_model_sanity():
    """Cost model: windowed plans are strictly cheaper than causal on the
    same schedule; balanced ships more bytes but runs fewer steps than
    ring; auto picks a capable schedule for every supported kind."""
    kw = dict(B=1, Hq=8, Hkv=8, Dqk=64, Dv=64, bpe=2)
    c_bal = sp.build_plan("balanced", mk.causal(), 8, 1024).cost(**kw)
    c_ring = sp.build_plan("ring", mk.causal(), 8, 1024).cost(**kw)
    assert c_bal.exec_steps < c_ring.exec_steps
    assert c_bal.flops_fwd < c_ring.flops_fwd      # helpers rebalance
    w_bal = sp.build_plan("balanced", mk.sliding_window(512), 8,
                          1024).cost(**kw)
    assert w_bal.flops_fwd < c_bal.flops_fwd
    assert w_bal.comm_bytes_fwd < c_bal.comm_bytes_fwd
    t = c_bal.time_estimate()
    assert t["step_s_lower_bound"] >= max(0.0, t["compute_s"] * 0.99)
    for m, seg in [(mk.causal(), False), (mk.sliding_window(64), False),
                   (mk.full(), False), (mk.document(), True),
                   (mk.document(boundaries=(0, 512)), False)]:
        name = sp.choose_schedule(m, 8, Tl=1024, Hq=6, Hkv=3, Dqk=64,
                                  dynamic_seg=seg)
        assert name in ("balanced", "ring", "ulysses")
    # prefix_lm: only ulysses can serve, and only FORWARD — the baselines
    # reuse the ring backward, which raises on prefix masks, so the
    # trace-time filter must mirror that (the capability/runtime
    # consistency bugfix): with include_bwd the resolution raises cleanly
    # instead of handing back a name that explodes at execution time
    assert sp.choose_schedule(mk.prefix_lm(8), 8, Tl=64, Hq=8, Hkv=8,
                              include_bwd=False) == "ulysses"
    with pytest.raises(ValueError, match="auto"):
        sp.choose_schedule(mk.prefix_lm(8), 8, Tl=64, Hq=8, Hkv=8,
                           include_bwd=True)
    # same for a non-causal sliding window
    assert sp.choose_schedule(mk.sliding_window(64, causal=False), 8,
                              Tl=64, Hq=8, Hkv=8,
                              include_bwd=False) == "ulysses"
    with pytest.raises(ValueError, match="auto"):
        sp.choose_schedule(mk.sliding_window(64, causal=False), 8, Tl=64,
                           Hq=8, Hkv=8)
    with pytest.raises(ValueError, match="auto"):
        sp.choose_schedule(mk.prefix_lm(8), 8, Tl=64, Hq=6, Hkv=3)


# --------------------------------------------------------------------------
# 2. Differential vs the frozen seed implementations (8 host devices)
# --------------------------------------------------------------------------

def test_plans_match_seed_implementations(subproc):
    """ACCEPTANCE: the plan executors reproduce the seed hand-written
    schedule loops (core/legacy_schedules.py) — forward, lse, and
    backward — for ring/balanced/zigzag × causal/windowed/document."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro import compat
from repro.core import mask as mk
from repro.core import legacy_schedules as LS
from repro.core.dist_attention import (DistAttnSpec, dist_attn_fwd,
                                       dist_attn_bwd, zigzag_perm)
mesh = jax.make_mesh((1,8), ("data","model"))
PS = jax.sharding.PartitionSpec
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(0),4)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D))
v = jax.random.normal(ks[2],(B,N,Hkv,D)); do = jax.random.normal(ks[3],(B,N,H,D))
bnd = mk.doc_boundaries(N, 5)
seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B,1)))
perm = zigzag_perm(N, 8)
qs = PS(None,"model",None,None); ls = PS(None,"model",None); gs = PS(None,"model")
def smap(f, ins, outs):
    return compat.shard_map(f, mesh=mesh, in_specs=ins, out_specs=outs,
                            check_vma=False)
cases = [
    ("ring", LS._fwd_ring, LS._bwd_ring, mk.causal(), False, False),
    ("ring", LS._fwd_ring, LS._bwd_ring, mk.sliding_window(100), False, False),
    ("ring", LS._fwd_ring, LS._bwd_ring, mk.full(), False, False),
    ("ring", LS._fwd_ring, LS._bwd_ring, mk.document(), True, False),
    ("balanced", LS._fwd_balanced, LS._bwd_balanced, mk.causal(), False, False),
    ("balanced", LS._fwd_balanced, LS._bwd_balanced, mk.document(), True, False),
    ("zigzag", LS._fwd_zigzag, LS._bwd_zigzag, mk.causal(), False, True),
    ("zigzag", LS._fwd_zigzag, LS._bwd_zigzag, mk.document(), True, True),
]
for sched, lf, lb, m, use_seg, zz in cases:
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=m)
    qq,kk_,vv,dd = (tuple(x[:,perm] for x in (q,k,v,do)) if zz
                    else (q,k,v,do))
    ss = seg[:,perm] if zz else seg
    if use_seg:
        fl = smap(lambda a,b,c,s: lf(spec,a,b,c,s), (qs,)*3+(gs,), (qs,ls))
        o_l, s_l = jax.jit(fl)(qq,kk_,vv,ss)
    else:
        fl = smap(lambda a,b,c: lf(spec,a,b,c), (qs,)*3, (qs,ls))
        o_l, s_l = jax.jit(fl)(qq,kk_,vv)
    segarg = ss if use_seg else None
    o_n, s_n = jax.jit(lambda *a: dist_attn_fwd(*a[:3], mesh=mesh, spec=spec,
        batch_axes=None, segments=segarg))(qq,kk_,vv)
    ef = float(jnp.abs(o_n-o_l).max()); es = float(jnp.abs(s_n-s_l).max())
    if use_seg:
        bl = smap(lambda a,b,c,o,s,d,g: lb(spec,a,b,c,o,s,d,g),
                  (qs,)*4+(ls,qs,gs), (qs,)*3)
        g_l = jax.jit(bl)(qq,kk_,vv,o_l,s_l,dd,ss)
    else:
        bl = smap(lambda a,b,c,o,s,d: lb(spec,a,b,c,o,s,d),
                  (qs,)*4+(ls,qs), (qs,)*3)
        g_l = jax.jit(bl)(qq,kk_,vv,o_l,s_l,dd)
    g_n = jax.jit(lambda *a: dist_attn_bwd(*a, mesh=mesh, spec=spec,
        batch_axes=None, segments=segarg))(qq,kk_,vv,o_l,s_l,dd)
    eb = max(float(jnp.abs(x-y).max()) for x,y in zip(g_n,g_l))
    assert max(ef,es,eb) < 5e-5, (sched, m.kind, ef, es, eb)
    print("OK seed-diff", sched, m.kind, ef, es, eb)
""")
    assert out.count("OK") == 8


# --------------------------------------------------------------------------
# 3. Oracle differentials for the new capabilities
# --------------------------------------------------------------------------

def test_windowed_balanced_zigzag_vs_oracle(subproc):
    """ACCEPTANCE: windowed balanced/zigzag (new with the plan IR) match
    the oracle forward + grads on 8 devices, and execute strictly fewer
    ring steps than their causal plans."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core import schedule as sp
from repro.core.dist_attention import (DistAttnSpec, dist_flash_attn,
                                       zigzag_perm)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(1),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D))
v = jax.random.normal(ks[2],(B,N,Hkv,D))
perm = zigzag_perm(N, 8); inv = np.argsort(perm)
for w in (10, 60, 300):
    m = mk.sliding_window(w)
    g_ref = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c,mask=m)
        .astype(jnp.float32)**2),(0,1,2))(q,k,v)
    o_ref = full_attn_ref(q,k,v,mask=m)
    for sched, zz in (("balanced",False), ("zigzag",True)):
        plan = sp.build_plan(sched, m, 8, N//8)
        causal = sp.build_plan(sched, mk.causal(), 8, N//8)
        # bands smaller than a shard must prune steps (zigzag keeps both
        # sequence-end steps, so its cut needs w below the half-chunk span)
        if w <= 60:
            assert plan.exec_steps < causal.exec_steps, (sched, w)
        assert plan.exec_steps <= causal.exec_steps, (sched, w)
        spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=m)
        a,b,c = ((q[:,perm],k[:,perm],v[:,perm]) if zz else (q,k,v))
        def loss(a,b,c):
            o,_ = dist_flash_attn(a,b,c,mesh,spec,None)
            return jnp.sum(o.astype(jnp.float32)**2), o
        (l,o), g = jax.jit(jax.value_and_grad(loss,(0,1,2),has_aux=True))(a,b,c)
        if zz:
            eo = float(jnp.abs(o[:,inv]-o_ref).max())
            eg = max(float(jnp.abs(x[:,inv]-y).max()) for x,y in zip(g,g_ref))
        else:
            eo = float(jnp.abs(o-o_ref).max())
            eg = max(float(jnp.abs(x-y).max()) for x,y in zip(g,g_ref))
        assert max(eo,eg) < 5e-5, (sched, w, eo, eg)
        print("OK windowed", sched, w, plan.exec_steps, "/", plan.total_steps)
""")
    assert out.count("OK") == 6


def test_boundary_documents_on_ring_family(subproc):
    """ACCEPTANCE: document(boundaries=…) now runs on ring/balanced/zigzag
    with NO segment arrays — executors derive per-shard segment IDs from
    the static layout — matching the segment-array oracle, fwd + grads."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import (DistAttnSpec, dist_flash_attn,
                                       zigzag_perm)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,Hkv,D = 2,512,4,2,32
ks = jax.random.split(jax.random.PRNGKey(2),3)
q = jax.random.normal(ks[0],(B,N,H,D)); k = jax.random.normal(ks[1],(B,N,Hkv,D))
v = jax.random.normal(ks[2],(B,N,Hkv,D))
bnd = mk.doc_boundaries(N, 5)
seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B,1)))
m = mk.document(boundaries=bnd)
o_ref = full_attn_ref(q,k,v, mask=mk.document(), segments=seg)
g_ref = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c,
    mask=mk.document(), segments=seg).astype(jnp.float32)**2),(0,1,2))(q,k,v)
perm = zigzag_perm(N, 8); inv = np.argsort(perm)
for sched, zz in (("ring",False), ("balanced",False), ("zigzag",True)):
    spec = DistAttnSpec(axis="model", axis_size=8, schedule=sched, mask=m)
    a,b,c = ((q[:,perm],k[:,perm],v[:,perm]) if zz else (q,k,v))
    def loss(a,b,c):
        o,_ = dist_flash_attn(a,b,c,mesh,spec,None)   # NO segments arg
        return jnp.sum(o.astype(jnp.float32)**2), o
    (l,o), g = jax.jit(jax.value_and_grad(loss,(0,1,2),has_aux=True))(a,b,c)
    if zz:
        eo = float(jnp.abs(o[:,inv]-o_ref).max())
        eg = max(float(jnp.abs(x[:,inv]-y).max()) for x,y in zip(g,g_ref))
    else:
        eo = float(jnp.abs(o-o_ref).max())
        eg = max(float(jnp.abs(x-y).max()) for x,y in zip(g,g_ref))
    assert max(eo,eg) < 5e-5, (sched, eo, eg)
    print("OK bnd-doc", sched, eo, eg)
""")
    assert out.count("OK") == 3


def test_auto_schedule_resolution(subproc):
    """ACCEPTANCE: schedule="auto" resolves to a valid schedule for every
    supported mask kind (exact vs oracle, fwd + grads where a distributed
    backward exists) and raises nowhere the explicit names succeed."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import (DistAttnSpec, dist_attn_fwd,
                                       dist_flash_attn)
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,8), ("data","model"))
B,N,H,D = 2,512,8,32
ks = jax.random.split(jax.random.PRNGKey(3),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
bnd = mk.doc_boundaries(N, 5)
seg = jnp.asarray(np.tile(mk.segments_from_boundaries(N, bnd), (B,1)))
cases = [
    (mk.causal(), None, full_attn_ref(q,k,v,causal=True)),
    (mk.sliding_window(64), None, full_attn_ref(q,k,v,mask=mk.sliding_window(64))),
    (mk.full(), None, full_attn_ref(q,k,v,causal=False)),
    (mk.document(), seg, full_attn_ref(q,k,v,mask=mk.document(),segments=seg)),
    (mk.document(boundaries=bnd), None,
     full_attn_ref(q,k,v,mask=mk.document(),segments=seg)),
    (mk.prefix_lm(100), None, full_attn_ref(q,k,v,mask=mk.prefix_lm(100))),
]
for m, segarg, o_ref in cases:
    spec = DistAttnSpec(axis="model", axis_size=8, schedule="auto", mask=m)
    o,_ = jax.jit(lambda *a: dist_attn_fwd(*a, mesh=mesh, spec=spec,
        batch_axes=None, segments=segarg))(q,k,v)
    err = float(jnp.abs(o-o_ref).max())
    assert err < 2e-5, (m.kind, err)
    print("OK auto fwd", m.kind, err)
# grads through auto (causal — the training path)
spec = DistAttnSpec(axis="model", axis_size=8, schedule="auto",
                    mask=mk.causal())
g = jax.jit(jax.grad(lambda a,b,c: jnp.sum(dist_flash_attn(a,b,c,mesh,spec,
    None)[0].astype(jnp.float32)**2),(0,1,2)))(q,k,v)
g_ref = jax.grad(lambda a,b,c: jnp.sum(full_attn_ref(a,b,c,causal=True)
    .astype(jnp.float32)**2),(0,1,2))(q,k,v)
err = max(float(jnp.abs(x-y).max()) for x,y in zip(g,g_ref))
assert err < 5e-5, err
print("OK auto grads", err)
# auto must not raise where explicit names succeed: GQA heads that break
# ulysses still resolve (to a plan schedule)
kg = jax.random.normal(ks[1],(B,N,2,D))
spec = DistAttnSpec(axis="model", axis_size=8, schedule="auto",
                    mask=mk.causal())
o,_ = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c, mesh=mesh, spec=spec,
    batch_axes=None))(q,kg,kg)
print("OK auto gqa")
""")
    assert out.count("OK") == 8


def test_single_device_mesh_plan_paths(subproc):
    """Differential on a 1-device mesh: every schedule (and auto)
    collapses to the local kernel with identical results."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, dist_attn_fwd
from repro.kernels.ref import full_attn_ref
mesh = jax.make_mesh((1,1), ("data","model"))
B,N,H,D = 2,128,4,16
ks = jax.random.split(jax.random.PRNGKey(4),3)
q,k,v = (jax.random.normal(kk,(B,N,H,D)) for kk in ks)
o_ref = full_attn_ref(q,k,v,causal=True)
for sched in ("auto","balanced","ring","zigzag","ulysses","rsa"):
    spec = DistAttnSpec(axis="model", axis_size=1, schedule=sched,
                        mask=mk.causal())
    o,_ = jax.jit(lambda a,b,c: dist_attn_fwd(a,b,c, mesh=mesh, spec=spec,
        batch_axes=None))(q,k,v)
    err = float(jnp.abs(o-o_ref).max())
    assert err < 2e-5, (sched, err)
    print("OK 1dev", sched)
""", devices=1)
    assert out.count("OK") == 6


# --------------------------------------------------------------------------
# 4. 2D (seq×head) factored plans
# --------------------------------------------------------------------------

def _factorizations(P):
    return [(r, u) for (r, u) in sp.factorizations(P) if u > 1]


def _head_partition_ok(p2):
    """Head routing simulator: the u devices partition the query heads
    exactly, and every local query slot's KV slot holds the KV head its
    GQA group maps to — scatter and replicate modes alike."""
    Hq, Hkv, u = p2.Hq, p2.Hkv, p2.u
    g = Hq // Hkv
    Hql = Hq // u
    seen = []
    for j in range(u):
        q_ids, kv_ids = sp.plan2d_head_map(p2, j)
        seen += list(q_ids)
        for i, gq in enumerate(q_ids):
            local_kv = kv_ids[i] if p2.kv_mode == "replicate" \
                else kv_ids[i // g]
            assert local_kv == gq // g, (p2.name, j, i, gq, local_kv)
    assert sorted(seen) == list(range(Hq))


@pytest.mark.parametrize("P", [2, 4, 8])
@pytest.mark.parametrize("mcase", ["causal", "windowed", "document"])
@pytest.mark.parametrize("heads", [(8, 8), (8, 2)], ids=["mha", "gqa"])
def test_plan2d_coverage_exactly_once(P, mcase, heads):
    """ACCEPTANCE: for every factorization r·u = P and every ring-family
    inner schedule, the 2D plan covers each global (q × kv) pair exactly
    once — the inner plan simulator runs at (P=r, Tl=u·Tl_dev) on the
    post-scatter layout — and the head partition is exact (GQA group map
    included, scatter and replicate KV modes)."""
    Hq, Hkv = heads
    Tl_dev = 8
    for r, u in _factorizations(P):
        T = r * u * Tl_dev
        m = {"causal": mk.causal(),
             "windowed": mk.sliding_window(max(3, T // 8)),
             "document": mk.document(boundaries=mk.doc_boundaries(T, 3)),
             }[mcase]
        for sched in ("ring", "balanced", "zigzag"):
            if not sp.plan2d_capable(sched, m, r=r, u=u, Hq=Hq, Hkv=Hkv):
                continue
            p2 = sp.build_plan2d(sched, m, r, u, Tl_dev, Hq=Hq, Hkv=Hkv)
            assert p2.inner.P == r and p2.inner.Tl == u * Tl_dev
            assert p2.kv_mode == ("scatter" if Hkv % u == 0
                                  else "replicate")
            _assert_exact(p2.inner)
            _head_partition_ok(p2)


def test_plan2d_windowed_pruning_intact():
    """Step pruning survives the factorization: a small window on the
    inner ring/balanced plan at r = 4 executes strictly fewer steps than
    causal, exactly as in 1D — the head scatter changes nothing about the
    seq-axis schedule."""
    for sched in ("ring", "balanced"):
        pc = sp.build_plan2d(sched, mk.causal(), 4, 2, 16, Hq=8, Hkv=8)
        pw = sp.build_plan2d(sched, mk.sliding_window(5), 4, 2, 16,
                             Hq=8, Hkv=8)
        assert pw.inner.exec_steps < pc.inner.exec_steps, sched
        _assert_exact(pw.inner)


def test_plan2d_capability_and_build_errors():
    """Capability edges: Hq must divide u; non-uniform GQA groups are
    rejected; r == 1 serves any mask kind through the local kernel; r > 1
    follows the 1D plan rules (no prefix_lm, no non-causal windows)."""
    assert not sp.plan2d_capable("ring", mk.causal(), r=2, u=4, Hq=6,
                                 Hkv=2)
    assert not sp.plan2d_capable("ring", mk.causal(), r=2, u=4, Hq=8,
                                 Hkv=3)
    assert sp.plan2d_capable("ring", mk.prefix_lm(8), r=1, u=8, Hq=8,
                             Hkv=2)
    assert sp.plan2d_capable(
        "ring", mk.sliding_window(9, causal=False), r=1, u=8, Hq=8, Hkv=8)
    assert not sp.plan2d_capable("ring", mk.prefix_lm(8), r=2, u=4, Hq=8,
                                 Hkv=8)
    assert not sp.plan2d_capable("balanced", mk.full(), r=4, u=2, Hq=8,
                                 Hkv=8)
    with pytest.raises(ValueError, match="factorization"):
        sp.build_plan2d("balanced", mk.full(), 4, 2, 8, Hq=8, Hkv=8)
    with pytest.raises(ValueError, match="factorization"):
        sp.build_plan2d("ring", mk.causal(), 2, 4, 8, Hq=6, Hkv=6)


def test_plan2d_cost_and_factorized_auto():
    """The (r, u) factorization space in the cost model: plan2d_cost
    reduces head-axis traffic claims to the roofline helpers, and
    ``choose_schedule(factorize=True)`` returns the cheapest capable
    triple — nontrivial (r > 1 and u > 1) for the causal-GQA bench
    regime, (r = 1, u = P) for prefix_lm (healing the no-backward gap of
    every 1D multi-shard schedule), and a clean error when nothing is
    capable."""
    kw = dict(Tl=256, B=1, Hq=8, Hkv=2, Dqk=64, bpe=4)
    name, r, u = sp.choose_schedule(mk.causal(), 8, factorize=True, **kw)
    assert r * u == 8 and r > 1 and u > 1, (name, r, u)
    assert name in ("ring", "balanced")
    # the chosen 2D factorization is analytically cheaper than both pure
    # extremes for this shape
    def t_of(nm, rr, uu):
        if uu == 1:
            c = sp.plan_cost(sp.build_plan(nm, mk.causal(), 8, 256),
                             B=1, Hq=8, Hkv=2, Dqk=64, bpe=4)
        else:
            c = sp.plan2d_cost(
                sp.build_plan2d(nm, mk.causal(), rr, uu, 256, Hq=8,
                                Hkv=2), B=1, Dqk=64, bpe=4)
        return c.time_estimate()["step_s_lower_bound"]
    assert t_of(name, r, u) <= t_of("ring", 8, 1)
    assert t_of(name, r, u) <= t_of("ring", 1, 8)
    # prefix_lm: the only backward-capable factorization is head-only
    nm, r1, u1 = sp.choose_schedule(mk.prefix_lm(64), 8, factorize=True,
                                    **kw)
    assert (nm, r1, u1) == ("ring", 1, 8)
    # heads that divide nothing: no factorization with u > 1 exists and
    # 1D plans still win where capable…
    nm, r2, u2 = sp.choose_schedule(mk.causal(), 8, Tl=256, Hq=7, Hkv=7,
                                    factorize=True)
    assert (r2, u2) == (8, 1)
    # …but prefix_lm + indivisible heads has no capable triple at all
    with pytest.raises(ValueError, match="factorization"):
        sp.choose_schedule(mk.prefix_lm(8), 8, Tl=64, Hq=7, Hkv=7,
                           factorize=True)
    assert sp.choose_schedule(mk.causal(), 1, Tl=64,
                              factorize=True) == ("ring", 1, 1)
    # cost model consistency: a 2D plan's comm includes the inner plan's
    c2 = sp.plan2d_cost(sp.build_plan2d("ring", mk.causal(), 4, 2, 256,
                                        Hq=8, Hkv=2), B=1, Dqk=64, bpe=4)
    ci = sp.plan_cost(sp.build_plan("ring", mk.causal(), 4, 512), B=1,
                      Hq=4, Hkv=1, Dqk=64, bpe=4)
    assert c2.comm_bytes_fwd > ci.comm_bytes_fwd
    assert c2.flops_fwd == ci.flops_fwd


def test_plans2d_match_1d_and_oracle(subproc):
    """ACCEPTANCE: 2D forward + grads == the 1D ring reference == the
    single-device oracle, for MHA and GQA (scatter and replicate KV
    modes), causal / windowed / document masks, across the (2, 4) and
    (4, 2) factorizations of the 8-device host mesh."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import DistAttnSpec, Mesh2DSpec, dist_flash_attn
from repro.core.attention import chunk_attn
B,N,D = 2,512,32
mesh1 = jax.make_mesh((1,8), ("data","model"))
key = jax.random.PRNGKey(0)
for (Hq,Hkv) in ((4,4),(4,2)):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B,N,Hq,D), jnp.float32)
    k = jax.random.normal(ks[1], (B,N,Hkv,D), jnp.float32)
    v = jax.random.normal(ks[2], (B,N,Hkv,D), jnp.float32)
    do = jax.random.normal(ks[3], (B,N,Hq,D), jnp.float32)
    bnd = mk.doc_boundaries(N, 3)
    for m in (mk.causal(), mk.sliding_window(60), mk.document(boundaries=bnd)):
        def loss_ref(q,k,v):
            o,_ = chunk_attn(q,k,v,mask=m,impl="ref")
            return jnp.sum(o*do)
        o_ref,_ = chunk_attn(q,k,v,mask=m,impl="ref")
        g_ref = jax.grad(loss_ref, argnums=(0,1,2))(q,k,v)
        spec1 = DistAttnSpec(axis="model", axis_size=8, schedule="ring", mask=m)
        def loss1(q,k,v):
            o,_ = dist_flash_attn(q,k,v,mesh1,spec1,batch_axes=None)
            return jnp.sum(o*do)
        o1,_ = dist_flash_attn(q,k,v,mesh1,spec1,batch_axes=None)
        g1 = jax.grad(loss1, argnums=(0,1,2))(q,k,v)
        for (r,u) in ((2,4),(4,2)):
            mesh2 = jax.make_mesh((1,r,u), ("data","seq","head"))
            sched = "balanced" if m.causal else "ring"
            spec2 = DistAttnSpec(axis="seq", axis_size=8, schedule=sched,
                                 mask=m, mesh2d=Mesh2DSpec(r=r,u=u))
            def loss2(q,k,v):
                o,_ = dist_flash_attn(q,k,v,mesh2,spec2,batch_axes=None)
                return jnp.sum(o*do)
            o2,_ = dist_flash_attn(q,k,v,mesh2,spec2,batch_axes=None)
            g2 = jax.grad(loss2, argnums=(0,1,2))(q,k,v)
            eo = max(float(jnp.max(jnp.abs(o2-o_ref))),
                     float(jnp.max(jnp.abs(o2-o1))))
            eg = max(max(float(jnp.max(jnp.abs(a-b))) for a,b in zip(g2,g_ref)),
                     max(float(jnp.max(jnp.abs(a-b))) for a,b in zip(g2,g1)))
            assert eo < 5e-5 and eg < 5e-5, (Hq,Hkv,m.kind,r,u,eo,eg)
            print(f"OK 2d {Hq}/{Hkv} {m.kind} r{r}u{u}")
""")
    assert out.count("OK") == 12


def test_plan2d_r1_zigzag_and_auto(subproc):
    """The factorization edges on real devices: the r == 1 head-only
    scatter serves prefix_lm and non-causal windows *with grads* (no 1D
    multi-shard schedule can), zigzag-2D matches the oracle under the
    caller's zigzag_perm(T, r) pre-permutation, and ``schedule="auto"``
    on a 2D spec resolves an inner schedule that runs."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import mask as mk
from repro.core.dist_attention import (DistAttnSpec, Mesh2DSpec,
                                       dist_flash_attn, zigzag_perm)
from repro.core.attention import chunk_attn
B,N,Hq,Hkv,D = 2,512,8,2,32
ks = jax.random.split(jax.random.PRNGKey(1), 4)
q = jax.random.normal(ks[0], (B,N,Hq,D), jnp.float32)
k = jax.random.normal(ks[1], (B,N,Hkv,D), jnp.float32)
v = jax.random.normal(ks[2], (B,N,Hkv,D), jnp.float32)
do = jax.random.normal(ks[3], (B,N,Hq,D), jnp.float32)
def check(label, m, r, u, sched):
    def loss_ref(q,k,v):
        o,_ = chunk_attn(q,k,v,mask=m,impl="ref")
        return jnp.sum(o*do)
    o_ref,_ = chunk_attn(q,k,v,mask=m,impl="ref")
    g_ref = jax.grad(loss_ref, argnums=(0,1,2))(q,k,v)
    mesh = jax.make_mesh((1,r,u), ("data","seq","head"))
    spec = DistAttnSpec(axis="seq", axis_size=8, schedule=sched,
                        mask=m, mesh2d=Mesh2DSpec(r=r,u=u))
    def loss(q,k,v):
        o,_ = dist_flash_attn(q,k,v,mesh,spec,batch_axes=None)
        return jnp.sum(o*do)
    o,_ = dist_flash_attn(q,k,v,mesh,spec,batch_axes=None)
    g = jax.grad(loss, argnums=(0,1,2))(q,k,v)
    eo = float(jnp.max(jnp.abs(o-o_ref)))
    eg = max(float(jnp.max(jnp.abs(a-b))) for a,b in zip(g,g_ref))
    assert eo < 5e-5 and eg < 5e-5, (label, eo, eg)
    print("OK", label)
check("prefix r1u8", mk.prefix_lm(100), 1, 8, "ring")
check("noncausal-window r1u8", mk.MaskSpec(causal=False, window=64), 1, 8, "ring")
check("auto r4u2", mk.causal(), 4, 2, "auto")
check("auto r2u4 windowed", mk.sliding_window(60), 2, 4, "auto")
# zigzag-2D under the caller pre-permutation with r (not P) chunks
r, u = 4, 2
perm = zigzag_perm(N, r); inv = np.argsort(perm)
m = mk.causal()
mesh = jax.make_mesh((1,r,u), ("data","seq","head"))
spec = DistAttnSpec(axis="seq", axis_size=8, schedule="zigzag",
                    mask=m, mesh2d=Mesh2DSpec(r=r,u=u))
o_ref,_ = chunk_attn(q,k,v,mask=m,impl="ref")
o2p,_ = dist_flash_attn(q[:,perm],k[:,perm],v[:,perm],mesh,spec,batch_axes=None)
assert float(jnp.max(jnp.abs(o2p[:,inv]-o_ref))) < 5e-5
print("OK zigzag2d r4u2")
""")
    assert out.count("OK") == 5
