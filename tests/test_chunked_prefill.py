"""Oracle differentials for chunked prefill and the shared-prefix cache:
streams from the paged engine — whole or chunked prefill, cold or warm
prefix cache, copy-on-write forks, windowed reclamation — must be
token-identical to the dense fixed-slot oracle and to cold-cache solo
runs, across mha/gqa/mla and causal/sliding-window masking.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.config import ShapeSpec, get_config, smoke_config
from repro.data.pipeline import SyntheticTokens
from repro.models.transformer import Runtime, build_model
from repro.parallel.sharding import make_parallel_config
from repro.serve.engine import Engine, FixedSlotEngine


def _setup(arch, window=0, prompt_len=24, batch=3):
    cfg = smoke_config(get_config(arch))
    if window:
        cfg = cfg.replace(attn=dataclasses.replace(cfg.attn, window=window))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shape = ShapeSpec("srv", prompt_len, batch, "prefill")
    par = make_parallel_config(mesh, shape)
    model = build_model(cfg, Runtime(mesh=mesh, par=par, impl="ref"))
    params = model.init(jax.random.PRNGKey(0))
    batch_d = SyntheticTokens(cfg, shape, par, mesh).batch(0)
    return cfg, model, params, batch_d


def _prompts(batch_d):
    return np.asarray(batch_d["tokens"])


def _solo_cold(model, params, prompt, *, n):
    """The canonical baseline: whole-prompt prefill, no prefix cache,
    request run alone."""
    eng = Engine(model, params, max_batch=4, block_size=8,
                 n_blocks=4 * (len(prompt) + n) // 8 + 8,
                 prefill_chunk_tokens=0, prefix_cache=False)
    rid = eng.submit(prompt, max_new_tokens=n)
    return eng.run()[rid]


def _drained_conservation(eng):
    eng.cache.allocator.check_conservation()
    assert eng.cache.allocator.n_free + eng.cache.n_cache_blocks \
        == eng.cache.allocator.n_usable
    if eng.cache.prefix is not None:
        eng.cache.prefix.check_integrity()


# ==========================================================================
# fixed-slot oracle differential: chunked × cold/warm × arch × mask
# ==========================================================================

@pytest.mark.parametrize("chunk", [0, 7, 16])
@pytest.mark.parametrize("arch,window",
                         [("smollm-360m", 0), ("llama-gqa", 0),
                          ("llama-gqa", 16),
                          pytest.param("deepseek-v2-lite-16b", 0,
                                       marks=pytest.mark.slow)])
def test_chunked_prefill_matches_fixed_slot_oracle(arch, window, chunk):
    """Cold pass: chunked prefill streams equal the dense oracle's.  Warm
    pass (same prompts resubmitted): the prefix cache must actually hit,
    and the streams must not change."""
    cfg, model, params, batch_d = _setup(arch, window=window)
    n = 6
    toks_fixed, _ = FixedSlotEngine(model, params).generate(batch_d, n)
    eng = Engine(model, params, max_batch=4, block_size=8, n_blocks=48,
                 prefill_chunk_tokens=chunk, prefix_cache=True)
    toks_cold = eng.generate(batch_d, n)
    np.testing.assert_array_equal(np.asarray(toks_fixed),
                                  np.asarray(toks_cold))
    hits_before = eng.stats()["hit_tokens"]
    toks_warm = eng.generate(batch_d, n)
    assert eng.stats()["hit_tokens"] > hits_before, \
        "warm pass should be served (partly) from the prefix cache"
    np.testing.assert_array_equal(np.asarray(toks_cold),
                                  np.asarray(toks_warm))
    _drained_conservation(eng)


# ==========================================================================
# windowed reclamation
# ==========================================================================

def test_windowed_reclamation_frees_blocks_and_matches_oracle():
    """Sliding-window serving reclaims blocks wholly below the window
    (freed storage, not masked storage) without perturbing the stream."""
    cfg, model, params, batch_d = _setup("llama-gqa", window=16,
                                         prompt_len=32, batch=2)
    n = 12
    toks_fixed, _ = FixedSlotEngine(model, params).generate(batch_d, n)
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=32,
                 prefill_chunk_tokens=8)
    toks_paged = eng.generate(batch_d, n)
    np.testing.assert_array_equal(np.asarray(toks_fixed),
                                  np.asarray(toks_paged))
    assert eng.stats()["reclaimed"] > 0, \
        "context grew past the window; blocks below it must be reclaimed"
    _drained_conservation(eng)


# ==========================================================================
# copy-on-write forks (engineered divergence)
# ==========================================================================

def test_partial_tail_hit_forks_before_chunk_write():
    """A request sharing a prefix that ends *inside* a cached block must
    fork that block before its chunk writes into it — and stream exactly
    as if it ran cold and alone."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=32,
                                         batch=2)
    prompts = _prompts(batch_d)
    donor = prompts[0][:25]                    # prefill 24 = 3 full blocks
    div = donor.copy()
    div[20:] = (div[20:] + 1) % cfg.vocab     # diverges mid-block-2
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=32,
                 prefill_chunk_tokens=8)
    eng.submit(donor, max_new_tokens=4)
    eng.run()
    assert eng.cache.n_cache_blocks >= 3
    r1 = eng.submit(div, max_new_tokens=4)
    out = eng.run()
    req = eng.requests[r1]
    assert req.n_hit == 20, "expected a partial-tail hit (2.5 blocks)"
    assert eng.stats()["forks"] >= 1, \
        "writing past the shared partial tail must fork the block"
    np.testing.assert_array_equal(out[r1],
                                  _solo_cold(model, params, div, n=4))
    # the donor's cached prefix must be untouched by the fork: a third
    # request with the donor's exact prompt still streams identically
    r2 = eng.submit(donor, max_new_tokens=4)
    out2 = eng.run()
    np.testing.assert_array_equal(out2[r2],
                                  _solo_cold(model, params, donor, n=4))
    _drained_conservation(eng)


def test_full_prefix_hit_forks_on_first_decode_write():
    """A request whose *entire* prefill is cached (prefix + partial tail)
    skips prefill chunks entirely; its first decode write lands inside a
    shared block and must fork it."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=32,
                                         batch=2)
    prompts = _prompts(batch_d)
    donor = prompts[0][:27]                    # prefill 26 = 3 full blocks
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=32,
                 prefill_chunk_tokens=8)
    eng.submit(donor, max_new_tokens=4)
    eng.run()
    short = donor[:23]                         # prefill 22: fully cached
    r1 = eng.submit(short, max_new_tokens=4)
    out = eng.run()
    req = eng.requests[r1]
    assert req.n_hit == 22 and req.n_hit == len(short) - 1, \
        "whole prefill should be served from the cache"
    assert eng.stats()["forks"] >= 1, \
        "decode writes into the shared tail block must fork it"
    np.testing.assert_array_equal(out[r1],
                                  _solo_cold(model, params, short, n=4))
    _drained_conservation(eng)


# ==========================================================================
# content-hash dedupe
# ==========================================================================

def test_same_step_duplicate_prompts_dedupe_onto_one_copy():
    """Two identical prompts admitted in the same step both miss the
    lookup and prefill privately; registration dedupes the second onto
    the first's canonical blocks (content addressing, not just prefix
    lookup), and both streams agree with the cold solo run."""
    cfg, model, params, batch_d = _setup("smollm-360m", prompt_len=24,
                                         batch=2)
    p = _prompts(batch_d)[0]
    eng = Engine(model, params, max_batch=2, block_size=8, n_blocks=32,
                 prefill_chunk_tokens=8)
    r0 = eng.submit(p, max_new_tokens=4)
    r1 = eng.submit(p, max_new_tokens=4)
    out = eng.run()
    assert eng.stats()["dedup_swaps"] > 0, \
        "the duplicate's full blocks must be swapped onto the canonical copy"
    np.testing.assert_array_equal(out[r0], out[r1])
    np.testing.assert_array_equal(out[r0], _solo_cold(model, params, p, n=4))
    _drained_conservation(eng)
