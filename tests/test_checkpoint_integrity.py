"""Checkpoint integrity: the CRC32 footer written by ``save`` must catch a
bit-flipped, truncated, or missing checkpoint member at ``restore`` time
with a structured :class:`CheckpointCorrupt` — never a cryptic
deserialization failure — while intact checkpoints round-trip exactly."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.io import checkpoint as ckpt
from repro.io.checkpoint import CheckpointCorrupt


def _tree():
    return {"params": {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
                       "b": jnp.ones((8,), jnp.bfloat16)},
            "scale": jnp.float32(3.0)}


def _saved(tmp_path):
    path = os.path.join(str(tmp_path), "ckpt")
    tree = _tree()
    ckpt.save(path, tree, step=7)
    return path, tree


def test_intact_checkpoint_round_trips(tmp_path):
    path, tree = _saved(tmp_path)
    assert ckpt.latest_step(path) == 7
    out = ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("offset", [0, 1000, -1])
def test_bit_flip_is_detected(tmp_path, offset):
    """Flip one bit anywhere in the weights file → CheckpointCorrupt
    naming the file, reason 'checksum'."""
    path, tree = _saved(tmp_path)
    wpath = os.path.join(path, "weights.npz")
    blob = bytearray(open(wpath, "rb").read())
    blob[offset % len(blob)] ^= 0x01
    open(wpath, "wb").write(bytes(blob))
    with pytest.raises(CheckpointCorrupt) as ei:
        ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree))
    assert ei.value.file == "weights.npz"
    assert ei.value.reason == "checksum"


def test_truncation_is_detected(tmp_path):
    path, tree = _saved(tmp_path)
    wpath = os.path.join(path, "weights.npz")
    blob = open(wpath, "rb").read()
    open(wpath, "wb").write(blob[:len(blob) // 2])
    with pytest.raises(CheckpointCorrupt) as ei:
        ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree))
    assert ei.value.reason == "truncated"


def test_missing_member_is_detected(tmp_path):
    path, tree = _saved(tmp_path)
    os.remove(os.path.join(path, "weights.npz"))
    with pytest.raises(CheckpointCorrupt) as ei:
        ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree))
    assert ei.value.reason == "missing" and ei.value.file == "weights.npz"


def test_tampered_manifest_is_detected(tmp_path):
    """The manifest checks itself: editing the recorded step (or the
    footers) without recomputing the payload checksum is caught."""
    path, tree = _saved(tmp_path)
    mpath = os.path.join(path, "manifest.json")
    m = json.load(open(mpath))
    m["step"] = 9999
    json.dump(m, open(mpath, "w"), indent=1, sort_keys=True)
    with pytest.raises(CheckpointCorrupt) as ei:
        ckpt.latest_step(path)
    assert ei.value.file == "manifest.json"
    assert ei.value.reason == "checksum"


def test_footerless_checkpoint_fails_closed(tmp_path):
    """A manifest with no integrity section (pre-footer format) is
    refused with a structured reason rather than trusted blindly."""
    path, tree = _saved(tmp_path)
    mpath = os.path.join(path, "manifest.json")
    m = json.load(open(mpath))
    del m["integrity"], m["manifest_crc32"]
    json.dump(m, open(mpath, "w"))
    with pytest.raises(CheckpointCorrupt) as ei:
        ckpt.restore(path, jax.tree.map(jnp.zeros_like, tree))
    assert ei.value.reason == "no_integrity"
