"""Mamba2 SSD: chunked algorithm vs the token-by-token recurrence oracle,
swept over chunk sizes and shapes (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.config import ModelConfig, SSMConfig
from repro.models.ssm import (ssm_apply, ssm_decode_step, ssm_params,
                              ssm_sequential_ref, _ssd_chunked)


def _cfg(d_state=16, head_dim=8, chunk=8, d_model=32):
    return ModelConfig(name="t", arch_type="ssm", n_layers=1,
                       d_model=d_model, d_ff=0, vocab=16, dtype="float32",
                       ssm=SSMConfig(d_state=d_state, d_conv=4, expand=2,
                                     head_dim=head_dim, chunk=chunk))


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 100), chunk=st.sampled_from([4, 8, 16, 64]))
def test_chunked_matches_sequential(seed, chunk):
    cfg = _cfg(chunk=chunk)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    p = ssm_params(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 64, 32)) * 0.5
    y_ref = ssm_sequential_ref(p, x, cfg)
    y = jax.jit(lambda p, x: ssm_apply(p, x, cfg, mesh=mesh,
                                       batch_axes=("data",)))(p, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-5)


def test_ssd_state_carry_composes():
    """Running SSD over [first half; second half] with the carried state
    equals running it over the full sequence (the invariant the cross-
    device relay relies on)."""
    b, t, nh, hd, N = 2, 32, 4, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, t, nh, hd))
    B = jax.random.normal(ks[1], (b, t, N)) * 0.3
    C = jax.random.normal(ks[2], (b, t, N)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, t, nh)))
    adt = -0.5 * dt
    s0 = jnp.zeros((b, nh, N, hd))
    y_full, s_full = _ssd_chunked(x, B, C, dt, adt, s0, chunk=8)
    h = t // 2
    y1, s1 = _ssd_chunked(x[:, :h], B[:, :h], C[:, :h], dt[:, :h],
                          adt[:, :h], s0, chunk=8)
    y2, s2 = _ssd_chunked(x[:, h:], B[:, h:], C[:, h:], dt[:, h:],
                          adt[:, h:], s1, chunk=8)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full), atol=1e-4)


def test_decode_step_matches_training_forward(mesh):
    """Greedy recurrent decode reproduces the training forward outputs
    position by position."""
    cfg = _cfg()
    p = ssm_params(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 24, 32)) * 0.5
    y_train = jax.jit(lambda p, x: ssm_apply(p, x, cfg, mesh=mesh,
                                             batch_axes=("data",)))(p, x)
    s = cfg.ssm
    state = jnp.zeros((1, s.n_heads(32), s.d_state, s.head_dim), jnp.float32)
    tail = jnp.zeros((1, s.d_conv - 1, s.d_inner(32) + 2 * s.d_state))
    outs = []
    for i in range(24):
        y, state, tail = ssm_decode_step(p, x[:, i:i + 1], state, tail, cfg)
        outs.append(y)
    y_dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               atol=5e-5)
