"""Oracle-differential tests for the paged KV cache + paged flash-decode.

Covers the ISSUE-5 acceptance surface:
  * allocator invariants (no double-alloc, owner-checked frees, free-list
    conservation, deterministic exhaustion);
  * paged-vs-dense decode differentials over randomly fragmented block
    tables (interleaved alloc/free, out-of-order blocks), for MHA / GQA /
    MLA-latent layouts and causal + sliding-window MaskSpecs, on every
    paged backend (ref / chunked-lax / pallas-interpret), to fp32
    tolerance;
  * the same differential on an 8-host-device mesh with a sharded pool;
  * per-request (B,) positions in the *dense* decode path (the satellite
    fix) + the scalar-broadcast shim's DeprecationWarning;
  * registry resolution of the ``paged`` capability flag.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import mask as mk
from repro.core.attention import paged_decode_attn
from repro.kernels import registry
from repro.serve.cache import BlockAllocator, PagedKVCache, PoolExhausted

TOL = 2e-5


# ==========================================================================
# allocator invariants
# ==========================================================================

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), n_blocks=st.integers(2, 24))
def test_allocator_invariants(seed, n_blocks):
    rng = np.random.default_rng(seed)
    al = BlockAllocator(n_blocks)
    live = {}                                     # rid -> ids
    for step in range(40):
        op = rng.integers(0, 3)
        if op < 2:                                # alloc for a new rid
            rid = int(rng.integers(0, 1 << 30))
            n = int(rng.integers(1, 4))
            if al.n_free < n:
                with pytest.raises(PoolExhausted):
                    al.alloc(rid, n)
                continue
            ids = al.alloc(rid, n)
            assert len(set(ids)) == n             # no double-alloc inside
            for prev in live.values():
                assert not set(ids) & set(prev)   # ... or across requests
            live[rid] = ids
        elif live:                                # free one rid
            rid = sorted(live)[int(rng.integers(0, len(live)))]
            al.free(live.pop(rid), rid)
        al.check_conservation()
    # double free / foreign free raise
    if live:
        rid, ids = next(iter(live.items()))
        with pytest.raises(ValueError):
            al.free(ids, rid + 1)
        al.free(ids, rid)
        with pytest.raises(ValueError):
            al.free(ids, rid)


def test_allocator_exhaustion_is_atomic_and_deterministic():
    a1, a2 = BlockAllocator(8), BlockAllocator(8)
    assert a1.alloc(1, 3) == a2.alloc(1, 3)       # same sequence, same ids
    free_before = a1.n_free
    with pytest.raises(PoolExhausted):
        a1.alloc(2, free_before + 1)
    assert a1.n_free == free_before               # nothing leaked
    a1.check_conservation()


# ==========================================================================
# fragmented-table construction shared by the differentials
# ==========================================================================

def _fragmented_tables(rng, al, B, nb, lengths, bs):
    """Allocate each request's blocks with interleaved alloc/free churn so
    tables are out-of-order and non-contiguous in the pool."""
    table = np.zeros((B, nb), np.int32)
    # churn: grab and release scratch requests to scramble the free list
    for b in range(B):
        scratch = al.alloc(999_000 + b, int(rng.integers(1, 3)))
        n = -(-int(lengths[b]) // bs)
        ids = al.alloc(b, n)
        al.free(scratch, 999_000 + b)
        # the table's virtual→pool mapping is arbitrary: scramble it so the
        # differentials cover out-of-pool-order tables
        table[b, :n] = rng.permutation(ids)
    return table


def _dense_rowwise_oracle(q, k_pool, v_pool, table, lengths, mask, scale):
    """Per-row numpy softmax attention over the contiguous gather."""
    B, _, Hq, Dq = q.shape
    bs, Hkv = k_pool.shape[1], k_pool.shape[2]
    g = Hq // Hkv
    sc = scale if scale is not None else 1.0 / np.sqrt(Dq)
    out = np.zeros((B, 1, Hq, v_pool.shape[-1]), np.float32)
    for b in range(B):
        L = int(lengths[b])
        nb = -(-L // bs)
        kk = np.concatenate([np.asarray(k_pool[table[b, i]])
                             for i in range(nb)], 0)[:L]
        vv = np.concatenate([np.asarray(v_pool[table[b, i]])
                             for i in range(nb)], 0)[:L]
        kk = np.repeat(kk, g, 1)
        vv = np.repeat(vv, g, 1)
        s = np.einsum("hd,khd->hk", np.asarray(q[b, 0], np.float64),
                      kk.astype(np.float64)) * sc
        if mask.window:
            j = np.arange(L)
            s = np.where((L - 1 - j)[None, :] < mask.window, s, -np.inf)
        p = np.exp(s - s.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        out[b, 0] = np.einsum("hk,khd->hd", p, vv.astype(np.float64))
    return out


LAYOUTS = {
    # Hq, Hkv, Dq, Dv
    "mha": (4, 4, 32, 32),
    "gqa": (6, 2, 16, 16),
    "mla": (4, 1, 48, 32),    # latent layout: Dv = narrow slice of Dk
}


@pytest.mark.parametrize("impl", ["ref", "chunked-lax", "pallas-interpret"])
@pytest.mark.parametrize("layout", sorted(LAYOUTS))
@pytest.mark.parametrize("window", [0, 11])
def test_paged_vs_dense_decode_differential(impl, layout, window):
    rng = np.random.default_rng(hash((impl, layout, window)) % 2 ** 31)
    Hq, Hkv, Dq, Dv = LAYOUTS[layout]
    B, bs, N = 4, 8, 32
    lengths = np.array([1, 7, 23, 40], np.int64)
    nb = -(-int(lengths.max()) // bs) + 1          # extra null-padded column
    al = BlockAllocator(N)
    table = _fragmented_tables(rng, al, B, nb, lengths, bs)
    assert any(np.any(np.diff(table[b][table[b] > 0]) < 0)
               for b in range(B)), "tables should be out of pool order"
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, Dq)), jnp.float32)
    k_pool = jnp.asarray(rng.standard_normal((N, bs, Hkv, Dq)), jnp.float32)
    if layout == "mla":                            # value = narrow k view
        v_pool = k_pool[..., :Dv]
    else:
        v_pool = jnp.asarray(rng.standard_normal((N, bs, Hkv, Dv)),
                             jnp.float32)
    mask = mk.sliding_window(window) if window else mk.causal()
    scale = 1.0 / np.sqrt(Dq + 7) if layout == "mla" else None
    o = paged_decode_attn(q, k_pool, v_pool, jnp.asarray(table),
                          jnp.asarray(lengths, jnp.int32), mask=mask,
                          scale=scale, impl=impl)
    ref = _dense_rowwise_oracle(q, k_pool, v_pool, table, lengths, mask,
                                scale)
    assert np.abs(np.asarray(o, np.float32) - ref).max() < TOL


def test_paged_decode_rejects_bad_masks_and_shapes():
    q = jnp.zeros((1, 1, 4, 8))
    kp = vp = jnp.zeros((4, 4, 4, 8))
    bt = jnp.zeros((1, 1), jnp.int32)
    ln = jnp.ones((1,), jnp.int32)
    with pytest.raises(ValueError, match="causal/sliding_window"):
        paged_decode_attn(q, kp, vp, bt, ln, mask=mk.document())
    with pytest.raises(ValueError, match="offset-free"):
        paged_decode_attn(q, kp, vp, bt, ln, mask=mk.causal(rel_offset=3))
    with pytest.raises(ValueError, match="query token"):
        paged_decode_attn(jnp.zeros((1, 0, 4, 8)), kp, vp, bt, ln)


# ==========================================================================
# PagedKVCache page-in / gather round trip
# ==========================================================================

def test_cache_page_in_gather_roundtrip():
    from repro.core.config import get_config, smoke_config
    cfg = smoke_config(get_config("llama-gqa"))
    cache = PagedKVCache.create(cfg, block_size=8, n_blocks=16, max_reqs=2)
    rng = np.random.default_rng(0)
    a = cfg.attn
    L = cfg.n_layers
    # fragment: slot 1 allocated between slot 0's two assignments
    T0, T1 = 19, 10
    cache.assign(0, rid=0, n_tokens=T0)
    cache.assign(1, rid=1, n_tokens=T1)
    for slot, T in ((0, T0), (1, T1)):
        dense = {
            "k": jnp.asarray(rng.standard_normal(
                (L, 1, T, a.n_kv_heads, a.head_dim)), jnp.float32),
            "v": jnp.asarray(rng.standard_normal(
                (L, 1, T, a.n_kv_heads, a.head_dim)), jnp.float32)}
        cache.page_in(slot, dense, T)
        got = cache.gather(slot, T)
        for key in ("k", "v"):
            np.testing.assert_allclose(np.asarray(got[key]),
                                       np.asarray(dense[key][:, 0]),
                                       atol=1e-6)
    # release returns every block; conservation holds
    cache.release(0, 0)
    cache.release(1, 1)
    cache.allocator.check_conservation()
    assert cache.allocator.n_free == cache.allocator.n_usable


# ==========================================================================
# dense decode path: per-request (B,) positions (satellite fix)
# ==========================================================================

def test_dense_decode_per_request_positions():
    """Mixed-length batch against a per-row oracle — the shared-scalar
    behavior this replaces could not express this at all."""
    from repro.core.dist_attention import dist_decode_attn
    rng = np.random.default_rng(3)
    B, S, Hq, D = 3, 24, 4, 16
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    pos = np.array([5, 17, 24], np.int64)
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    kc = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    vc = jnp.asarray(rng.standard_normal((B, S, Hq, D)), jnp.float32)
    k1 = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    v1 = jnp.asarray(rng.standard_normal((B, 1, Hq, D)), jnp.float32)
    for window in (0, 7):
        m = mk.sliding_window(window) if window else mk.causal()
        o = dist_decode_attn(q, kc, vc, k1, v1, mesh=mesh,
                             seq_axes=("model",), batch_axes=None,
                             mask=m, pos=jnp.asarray(pos, jnp.int32))
        for b in range(B):
            L = int(pos[b])
            kk = np.concatenate([np.asarray(kc[b, :L]),
                                 np.asarray(k1[b])], 0)
            vv = np.concatenate([np.asarray(vc[b, :L]),
                                 np.asarray(v1[b])], 0)
            s = np.einsum("hd,khd->hk", np.asarray(q[b, 0], np.float64),
                          kk.astype(np.float64)) / np.sqrt(D)
            if window:
                j = np.arange(L + 1)
                s = np.where((L - j)[None, :] < window, s, -np.inf)
            p = np.exp(s - s.max(-1, keepdims=True))
            p /= p.sum(-1, keepdims=True)
            ref = np.einsum("hk,khd->hd", p, vv.astype(np.float64))
            assert np.abs(np.asarray(o[b, 0], np.float64) - ref).max() \
                < TOL, (window, b)


def test_scalar_pos_shim_warns_once():
    from repro.core import mask as mkm
    from repro.core.dist_attention import dist_decode_attn
    site = "dist_decode_attn(pos=<scalar>)"
    mkm._DEPRECATION_WARNED.discard(site)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    z = jnp.zeros((2, 1, 2, 4))
    zc = jnp.zeros((2, 8, 2, 4))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        dist_decode_attn(z, zc, zc, z, z, mesh=mesh, seq_axes=("model",),
                         batch_axes=None, mask=mk.causal(),
                         pos=jnp.int32(8))
        dist_decode_attn(z, zc, zc, z, z, mesh=mesh, seq_axes=("model",),
                         batch_axes=None, mask=mk.causal(),
                         pos=jnp.int32(8))
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)
           and site in str(x.message)]
    assert len(dep) == 1                          # one-shot


# ==========================================================================
# registry: the paged capability flag
# ==========================================================================

def test_registry_paged_capability_and_fallback():
    for name in ("ref", "chunked-lax", "pallas", "pallas-interpret"):
        assert registry.get(name).paged, name
    assert not registry.get("null").paged
    # pallas on cpu walks its chain to a paged-capable host backend
    be = registry.resolve("pallas", "cpu", mask=mk.causal(), paged=True)
    assert be.paged and be.name in ("pallas-interpret", "chunked-lax")
    # null has no paged path and no fallback: explicit request raises
    with pytest.raises(ValueError, match="no paged"):
        registry.resolve("null", "cpu", mask=mk.causal(), paged=True)


# ==========================================================================
# 8-device mesh: sharded pool differential
# ==========================================================================

def test_paged_decode_8dev_sharded_pool(subproc):
    """The pool's block axis shards over the 8-device ``model`` axis; the
    gather crosses devices via GSPMD, and the result must equal the
    replicated single-mesh math to fp32 tolerance."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import mask as mk
from repro.core.attention import paged_decode_attn
rng = np.random.default_rng(0)
B, Hq, Hkv, Dq, bs, nb, N = 4, 4, 2, 16, 8, 4, 32
q = jnp.asarray(rng.standard_normal((B,1,Hq,Dq)), jnp.float32)
kp = jnp.asarray(rng.standard_normal((N,bs,Hkv,Dq)), jnp.float32)
vp = jnp.asarray(rng.standard_normal((N,bs,Hkv,Dq)), jnp.float32)
bt = jnp.asarray(rng.permutation(np.arange(1, N))[:B*nb].reshape(B,nb),
                 jnp.int32)
lens = jnp.asarray([3, 9, 17, 31], jnp.int32)
mask = mk.sliding_window(13)
o_local = paged_decode_attn(q, kp, vp, bt, lens, mask=mask, impl="ref")
mesh = jax.make_mesh((1, 8), ("data", "model"))
sh = NamedSharding(mesh, P("model", None, None, None))
kp_s, vp_s = jax.device_put(kp, sh), jax.device_put(vp, sh)
f = jax.jit(lambda *a: paged_decode_attn(*a, mask=mask, impl="ref"))
o_shard = f(q, kp_s, vp_s, bt, lens)
err = float(jnp.abs(o_shard - o_local).max())
assert err < 2e-5, err
print("OK sharded-pool err", err)
""")
    assert "OK sharded-pool" in out
