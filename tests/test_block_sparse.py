"""Brute-force verification of the static block-sparsity ranges every
pruned kernel derives its iteration space from (kernels/block_sparse.py):
for each block, the predicted valid/interior ranges must cover exactly
(valid) or conservatively (interior, prefix hull) the ground truth computed
from the dense position mask — across causal / window / prefix_lm /
document MaskSpecs."""
import itertools

import numpy as np
import pytest

from repro.core.mask import MaskSpec, causal, doc_boundaries, document
from repro.kernels import block_sparse as bs


def _dense_mask(br, bc, nq, nk, m: MaskSpec):
    """(Tq, Tk) boolean attend-mask, same semantics as MaskSpec.allow."""
    qp = m.q_offset + np.arange(nq * br)
    kp = m.kv_offset + np.arange(nk * bc)
    out = np.ones((nq * br, nk * bc), dtype=bool)
    pre = (kp < m.prefix_len)[None, :] if m.prefix_len else None
    if m.causal:
        c = kp[None, :] <= qp[:, None]
        out &= (c | pre) if pre is not None else c
    if m.window and m.window > 0:
        w = (qp[:, None] - kp[None, :]) < m.window
        out &= (w | pre) if pre is not None else w
    if m.document:
        seg_q = np.searchsorted(m.boundaries, qp, side="right")
        seg_k = np.searchsorted(m.boundaries, kp, side="right")
        out &= seg_q[:, None] == seg_k[None, :]
    return out


def _sweep():
    masks = []
    for (c, rel, window) in itertools.product(
            [False, True], [-96, -16, 0, 16, 96], [0, 1, 24, 1000]):
        masks.append(MaskSpec(causal=c, window=window, q_offset=rel))
    # prefix_lm (hull bounds) and static-boundary documents
    for pre in (1, 20, 64, 500):
        masks.append(MaskSpec(causal=True, prefix_len=pre))
        masks.append(MaskSpec(causal=True, window=24, prefix_len=pre,
                              q_offset=16))
    for bnd in ((0,), (0, 30), (0, 17, 40, 41), (0, 64, 128)):
        masks.append(document(boundaries=bnd))
        masks.append(document(boundaries=bnd, window=24))
        masks.append(MaskSpec(document=True, boundaries=bnd))  # doc-only
    return list(itertools.product([16, 32], [16, 48], [1, 3, 4], [1, 2, 5],
                                  masks))


@pytest.mark.parametrize("br,bc", [(16, 16), (16, 48), (32, 16), (32, 48)])
def test_block_bounds_match_dense_mask(br, bc):
    """kv/q bounds agree with any() of the dense mask for every block of
    every sweep config (hull: predicted range must contain every non-empty
    block and, for contiguous kinds, nothing more); interior bounds must
    only ever cover all-True blocks."""
    for (br_, bc_, nq, nk, m) in _sweep():
        if (br_, bc_) != (br, bc):
            continue
        dm = _dense_mask(br, bc, nq, nk, m)
        hull = bool(m.prefix_len)     # prefix makes ranges a hull, not exact
        kw = dict(br=br, bc=bc, mask=m)
        for i in range(nq):
            lo, hi = bs.kv_block_bounds(i, nk=nk, **kw)
            lo_f, hi_f = bs.interior_kv_bounds(i, nk=nk, **kw)
            assert 0 <= lo and hi <= nk - 1
            for j in range(nk):
                tile = dm[i * br:(i + 1) * br, j * bc:(j + 1) * bc]
                cfg = (br, bc, nq, nk, m, i, j)
                if hull:
                    assert (lo <= j <= hi) or not tile.any(), cfg
                else:
                    assert (lo <= j <= hi) == bool(tile.any()), cfg
                # interior is conservative: inside => all-True
                if lo_f <= j <= hi_f:
                    assert tile.all(), cfg
                elif not (m.document or m.prefix_len):
                    # causal/window interiors are exact
                    assert not tile.all() or not tile.size, cfg
        for j in range(nk):
            lo_q, hi_q = bs.q_block_bounds(j, nq=nq, **kw)
            for i in range(nq):
                tile = dm[i * br:(i + 1) * br, j * bc:(j + 1) * bc]
                cfg = (br, bc, nq, nk, m, i, j)
                if hull:
                    assert (lo_q <= i <= hi_q) or not tile.any(), cfg
                else:
                    assert (lo_q <= i <= hi_q) == bool(tile.any()), cfg


def test_profiles_count_the_same_valid_pairs():
    """The fwd/dq orientation (rows = q blocks) and the dkv orientation
    (rows = kv blocks) execute the same set of valid (i, j) pairs."""
    for (br, bc, nq, nk, m) in _sweep():
        if m.prefix_len:
            continue                   # hull ranges differ per orientation
        kw = dict(nq=nq, nk=nk, br=br, bc=bc, mask=m)
        pk, pq = bs.kv_profile(**kw), bs.q_profile(**kw)
        assert pk.executed_steps == pq.executed_steps, (br, bc, nq, nk, m)
        assert pk.full_steps == pq.full_steps == nq * nk
        assert pk.executed_steps <= pk.launched_steps <= pk.full_steps
        assert pk.seq_grid == max(pk.row_counts, default=0)


def test_local_causal_chunk_work_ratio():
    """The acceptance target: the local causal chunk (rel=0, Tq=Tk) at
    nq = nk ≥ 8 executes ≥1.5x fewer grid steps than the dense sweep."""
    for n in (8, 16):
        p = bs.kv_profile(nq=n, nk=n, br=128, bc=128, mask=causal())
        assert p.executed_steps == n * (n + 1) // 2      # exact trapezoid
        assert p.work_ratio >= 1.5, (n, p.work_ratio)
        pq = bs.q_profile(nq=n, nk=n, br=128, bc=128, mask=causal())
        assert pq.executed_steps == p.executed_steps


def test_document_prunes_below_dense_causal():
    """Packed-batch acceptance: a document mask (static boundaries) executes
    strictly fewer grid steps than the dense causal mask over the same
    sequence — the cross-document blocks are gone."""
    T, n = 1024, 8
    br = bc = T // n
    bnd = doc_boundaries(T, 4)
    pc = bs.kv_profile(nq=n, nk=n, br=br, bc=bc, mask=causal())
    pd = bs.kv_profile(nq=n, nk=n, br=br, bc=bc,
                       mask=document(boundaries=bnd))
    assert pd.executed_steps < pc.executed_steps < pd.full_steps
    pq = bs.q_profile(nq=n, nk=n, br=br, bc=bc,
                      mask=document(boundaries=bnd))
    assert pq.executed_steps == pd.executed_steps


def test_degenerate_ranges():
    """All-masked and all-unmasked edges of the range computation."""
    # q chunk entirely before the kv chunk: causal masks everything
    p = bs.kv_profile(nq=2, nk=2, br=64, bc=64, mask=causal(-128))
    assert p.executed_steps == 0 and p.seq_grid == 0
    assert p.work_ratio == float("inf")
    # no mask at all: pruning must be the identity
    p = bs.kv_profile(nq=3, nk=5, br=64, bc=64, mask=MaskSpec())
    assert p.executed_steps == p.full_steps == 15
    assert p.row_counts == (5, 5, 5)
    # window beyond the whole kv chunk: also the identity (causal only)
    p = bs.kv_profile(nq=2, nk=2, br=64, bc=64,
                      mask=MaskSpec(causal=True, window=10_000, q_offset=64))
    assert p.row_counts == (2, 2)
    # dynamic-segment documents: the causal half still prunes, but the
    # segment half needs runtime arrays — so no mask-free interior exists
    m = document()
    assert m.needs_segments
    assert m.prunable                  # via the causal component
    lo_f, hi_f = bs.interior_kv_bounds(0, br=64, bc=64, nk=4, mask=m)
    assert hi_f < lo_f                 # no mask-free interior
    # document-only (causal dropped, e.g. a ring step) with dynamic
    # segments: nothing static to prune at all
    assert not m.replace(causal=False).prunable


def test_traced_bounds_match_python_bounds():
    """The same formulas under jax tracing (kernel bodies / index maps)
    produce the same numbers as the Python path (grid sizing)."""
    import jax
    import jax.numpy as jnp

    for m in (MaskSpec(causal=True, window=40, q_offset=48),
              document(boundaries=(0, 37, 80), window=40, rel_offset=16),
              MaskSpec(causal=True, prefix_len=33)):
        kw = dict(br=32, bc=16, nk=7, mask=m)

        @jax.jit
        def traced(i, kw=kw):
            lo, hi = bs.kv_block_bounds(i, **kw)
            lo_f, hi_f = bs.interior_kv_bounds(i, **kw)
            return jnp.stack([lo, hi, lo_f, hi_f])

        for i in range(4):
            want = (*bs.kv_block_bounds(i, **kw),
                    *bs.interior_kv_bounds(i, **kw))
            got = tuple(int(x) for x in traced(jnp.int32(i)))
            assert got == want, (m, i, got, want)

        @jax.jit
        def traced_q(j, kw=kw):
            kwq = dict(br=kw["br"], bc=kw["bc"], nq=5, mask=kw["mask"])
            return jnp.stack(bs.q_block_bounds(j, **kwq))

        for j in range(4):
            want = bs.q_block_bounds(j, br=32, bc=16, nq=5, mask=m)
            got = tuple(int(x) for x in traced_q(jnp.int32(j)))
            assert got == want, (m, j, got, want)
