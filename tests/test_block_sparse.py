"""Brute-force verification of the static block-sparsity ranges every
pruned kernel derives its iteration space from (kernels/block_sparse.py):
for each block, the predicted valid/interior ranges must equal the ground
truth computed from the dense position mask."""
import itertools

import numpy as np
import pytest

from repro.kernels import block_sparse as bs


def _dense_mask(br, bc, nq, nk, causal, rel, window):
    """(Tq, Tk) boolean attend-mask, same semantics as kernels' _pos_mask."""
    qp = rel + np.arange(nq * br)
    kp = np.arange(nk * bc)
    m = np.ones((nq * br, nk * bc), dtype=bool)
    if causal:
        m &= kp[None, :] <= qp[:, None]
    if window and window > 0:
        m &= (qp[:, None] - kp[None, :]) < window
    return m


SWEEP = list(itertools.product(
    [16, 32],                 # br
    [16, 48],                 # bc
    [1, 3, 4],                # nq
    [1, 2, 5],                # nk
    [False, True],            # causal
    [-96, -16, 0, 16, 96],    # rel_offset
    [0, 1, 24, 1000],         # window
))


@pytest.mark.parametrize("br,bc", [(16, 16), (16, 48), (32, 16), (32, 48)])
def test_block_bounds_match_dense_mask(br, bc):
    """kv/q/interior bounds agree with any()/all() of the dense mask for
    every block of every sweep config."""
    for (br_, bc_, nq, nk, causal, rel, window) in SWEEP:
        if (br_, bc_) != (br, bc):
            continue
        m = _dense_mask(br, bc, nq, nk, causal, rel, window)
        kw = dict(br=br, bc=bc, causal=causal, rel_offset=rel, window=window)
        for i in range(nq):
            lo, hi = bs.kv_block_bounds(i, nk=nk, **kw)
            lo_f, hi_f = bs.interior_kv_bounds(i, nk=nk, **kw)
            assert 0 <= lo and hi <= nk - 1
            for j in range(nk):
                tile = m[i * br:(i + 1) * br, j * bc:(j + 1) * bc]
                cfg = (br, bc, nq, nk, causal, rel, window, i, j)
                assert (lo <= j <= hi) == bool(tile.any()), cfg
                assert (lo_f <= j <= hi_f) == bool(tile.all()), cfg
        for j in range(nk):
            lo_q, hi_q = bs.q_block_bounds(j, nq=nq, **kw)
            for i in range(nq):
                tile = m[i * br:(i + 1) * br, j * bc:(j + 1) * bc]
                cfg = (br, bc, nq, nk, causal, rel, window, i, j)
                assert (lo_q <= i <= hi_q) == bool(tile.any()), cfg


def test_profiles_count_the_same_valid_pairs():
    """The fwd/dq orientation (rows = q blocks) and the dkv orientation
    (rows = kv blocks) execute the same set of valid (i, j) pairs."""
    for (br, bc, nq, nk, causal, rel, window) in SWEEP:
        kw = dict(nq=nq, nk=nk, br=br, bc=bc, causal=causal,
                  rel_offset=rel, window=window)
        pk, pq = bs.kv_profile(**kw), bs.q_profile(**kw)
        assert pk.executed_steps == pq.executed_steps, (br, bc, nq, nk,
                                                        causal, rel, window)
        assert pk.full_steps == pq.full_steps == nq * nk
        assert pk.executed_steps <= pk.launched_steps <= pk.full_steps
        assert pk.seq_grid == max(pk.row_counts, default=0)


def test_local_causal_chunk_work_ratio():
    """The acceptance target: the local causal chunk (rel=0, Tq=Tk) at
    nq = nk ≥ 8 executes ≥1.5x fewer grid steps than the dense sweep."""
    for n in (8, 16):
        p = bs.kv_profile(nq=n, nk=n, br=128, bc=128, causal=True,
                          rel_offset=0, window=0)
        assert p.executed_steps == n * (n + 1) // 2      # exact trapezoid
        assert p.work_ratio >= 1.5, (n, p.work_ratio)
        pq = bs.q_profile(nq=n, nk=n, br=128, bc=128, causal=True,
                          rel_offset=0, window=0)
        assert pq.executed_steps == p.executed_steps


def test_degenerate_ranges():
    """All-masked and all-unmasked edges of the range computation."""
    # q chunk entirely before the kv chunk: causal masks everything
    p = bs.kv_profile(nq=2, nk=2, br=64, bc=64, causal=True,
                      rel_offset=-128, window=0)
    assert p.executed_steps == 0 and p.seq_grid == 0
    assert p.work_ratio == float("inf")
    # no mask at all: pruning must be the identity
    p = bs.kv_profile(nq=3, nk=5, br=64, bc=64, causal=False,
                      rel_offset=0, window=0)
    assert p.executed_steps == p.full_steps == 15
    assert p.row_counts == (5, 5, 5)
    # window beyond the whole kv chunk: also the identity (causal only)
    p = bs.kv_profile(nq=2, nk=2, br=64, bc=64, causal=True,
                      rel_offset=64, window=10_000)
    assert p.row_counts == (2, 2)


def test_traced_bounds_match_python_bounds():
    """The same formulas under jax tracing (kernel bodies / index maps)
    produce the same numbers as the Python path (grid sizing)."""
    import jax
    import jax.numpy as jnp

    kw = dict(br=32, bc=16, nk=7, causal=True, rel_offset=48, window=40)

    @jax.jit
    def traced(i):
        lo, hi = bs.kv_block_bounds(i, **kw)
        lo_f, hi_f = bs.interior_kv_bounds(i, **kw)
        return jnp.stack([lo, hi, lo_f, hi_f])

    for i in range(4):
        want = (*bs.kv_block_bounds(i, **kw), *bs.interior_kv_bounds(i, **kw))
        got = tuple(int(x) for x in traced(jnp.int32(i)))
        assert got == want, (i, got, want)
